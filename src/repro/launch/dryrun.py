import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers + compiles.

For each combination this script builds the step function (through the
repro.core graph + §10 lowering), jits it with the mesh shardings, lowers
against ShapeDtypeStruct stand-ins (no allocation), compiles, and records
``memory_analysis()`` / ``cost_analysis()`` / the collective schedule into
``experiments/dryrun/<arch>__<shape>__<mesh>.json`` for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--archs a,b] [--shapes s,t]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from ..configs import ALIASES, get_config
from ..models.api import SHAPES
from ..parallel import sharding as shd
from . import mesh as mesh_mod
from . import roofline as roofline_mod
from .steps import build_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            rules_overrides: Optional[Dict[str, Any]] = None,
            hparam_overrides: Optional[Dict[str, Any]] = None,
            out_dir: Optional[str] = None,
            tag: str = "", verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2pod_512" if multi_pod else "1pod_256"
    n_dev = int(np_prod(mesh.devices.shape))
    rules = mesh_mod.mesh_rules(mesh, overrides=rules_overrides)

    t0 = time.time()
    with shd.axis_rules(rules, mesh):
        bundle = build_step(cfg, shape_name, mesh, rules,
                            hparam_overrides=hparam_overrides)
        jf = jax.jit(bundle.fn,
                     in_shardings=(bundle.feed_shardings, bundle.var_shardings),
                     out_shardings=bundle.out_shardings,
                     donate_argnums=(1,))
        lowered = jf.lower(bundle.feed_specs, bundle.var_specs)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    rl = roofline_mod.analyze(compiled, arch=arch, shape=shape,
                              mesh_name=mesh_name, n_devices=n_dev,
                              cfg=cfg, model=bundle.model)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": bundle.kind, "n_devices": n_dev,
        "compile_seconds": round(t1 - t0, 2),
        "graph_nodes": bundle.graph_nodes,
        "memory_analysis": rl.memory,
        "per_device_total_bytes": (mem.argument_size_in_bytes
                                   + mem.temp_size_in_bytes
                                   + mem.output_size_in_bytes
                                   - mem.alias_size_in_bytes),
        "roofline": rl.to_dict(),
        "rules_overrides": rules_overrides or {},
        "hparam_overrides": {k: str(v) for k, v in (hparam_overrides or {}).items()},
        "tag": tag,
    }
    if verbose:
        hbm = record["per_device_total_bytes"] / 2**30
        print(f"[dryrun] {arch:20s} {shape_name:12s} {mesh_name}: "
              f"compile {record['compile_seconds']:6.1f}s  "
              f"HBM/dev {hbm:6.2f} GiB  dominant={rl.dominant:10s} "
              f"c/m/coll = {rl.compute_s*1e3:.1f}/{rl.memory_s*1e3:.1f}/"
              f"{rl.collective_s*1e3:.1f} ms  useful={rl.useful_ratio:.2f}",
              flush=True)

    od = out_dir or OUT_DIR
    os.makedirs(od, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(od, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def np_prod(shape) -> int:
    n = 1
    for s in shape:
        n *= s
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id (assignment name)")
    ap.add_argument("--shape", choices=list(SHAPES), help="input shape")
    ap.add_argument("--all", action="store_true", help="run every combination")
    ap.add_argument("--archs", help="comma list (with --all)")
    ap.add_argument("--shapes", help="comma list (with --all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 = 512-chip mesh (default: 16x16)")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args(argv)

    archs = (args.archs.split(",") if args.archs else list(ALIASES))
    shapes = (args.shapes.split(",") if args.shapes else list(SHAPES))
    combos = ([(args.arch, args.shape)] if not args.all
              else [(a, s) for a in archs for s in shapes])

    failures = []
    for arch, shape in combos:
        try:
            run_one(arch, shape, multi_pod=args.multi_pod, out_dir=args.out_dir)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape, repr(e)))
            print(f"[dryrun] FAIL {arch} {shape}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        return 1
    print(f"all {len(combos)} dry-runs compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
