"""Roofline-term derivation from a compiled dry-run artifact.

Per (arch × shape × mesh) we derive three per-step time lower bounds from
the SPMD-partitioned per-device HLO module:

    compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS          (197 TF/s bf16)
    memory_s     = HLO_bytes_per_device / HBM_BW              (819 GB/s)
    collective_s = collective_bytes_per_device / LINK_BW      (~50 GB/s/link)

FLOPs, HBM traffic and collective wire bytes come from the trip-count-
aware HLO analyzer (hlo_analysis.py) over the SPMD-partitioned module —
``compiled.cost_analysis()`` visits ``while`` bodies once and therefore
under-reports scanned-layer models by ~n_layers; we record its raw
numbers alongside for reference.  MODEL_FLOPS = 6·N·D (train) or 2·N·D
(inference), N = active parameters, D = tokens — the MODEL/HLO ratio
exposes remat, padding and dispatch waste.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12      # bf16 per chip, TPU v5e
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    if not dims:
        return b
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * b


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result bytes per collective kind from per-device HLO."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if m is None:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None or f"{kind}-done(" in rhs:
            continue  # count start, not done
        # result shape(s) precede the op name
        head = rhs.split(f"{kind}", 1)[0]
        nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, Dict[str, float]]
    memory: Dict[str, float]
    model_flops_global: float
    cost_analysis_raw: Dict[str, float] = dataclasses.field(default_factory=dict)
    loops: Any = None
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops_per_device / PEAK_FLOPS
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.collective_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["useful_ratio"] = self.useful_ratio
        return d


def model_flops(cfg, n_params_active: int, shape) -> float:
    """6·N·D train, 2·N·D inference (D = tokens this step)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n_params_active * tokens


def active_params(cfg, model) -> int:
    """Active parameter count (MoE: routed experts scaled by top_k/E) using
    TRUE (unpadded) dimensions."""
    from ..models.api import Model
    from ..models.params import count_params

    true_model = Model.for_config(cfg, shard=1)
    total = count_params(true_model.describe_params())
    if not cfg.n_experts:
        return total
    # routed expert params per layer (w1,w3,w2) at true expert count
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed = cfg.n_layers * cfg.n_experts * per_expert
    active_frac = cfg.top_k / cfg.n_experts
    return int(total - routed + routed * active_frac)


def analyze(compiled, *, arch: str, shape, mesh_name: str, n_devices: int,
            cfg, model) -> Roofline:
    from .hlo_analysis import analyze_text

    ca = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    st = analyze_text(compiled.as_text(), n_devices)
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=st.flops,
        bytes_per_device=st.hbm_bytes,
        collective_bytes_per_device=st.collective_bytes,
        collectives=st.collectives,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        model_flops_global=model_flops(cfg, active_params(cfg, model), shape),
        cost_analysis_raw={k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed")},
        loops=st.loops[:50],
    )
