"""Batched serving driver: prefill + decode with the cache-as-Variable
graph (deliverable (b): serving example).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \\
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.options import SessionOptions
from ..models.api import Model, Shape
from ..models.params import init_params
from ..obs import metrics as obs_metrics
from .cli import add_cluster_options, add_engine_options, add_obs_options
from .steps import build_serve_step, build_eager_serve_step


def print_metrics(label: str = "serve") -> None:
    """One-line §16.4 registry digest: serving latency percentiles when
    any request completed, plus the distrib counters when non-zero."""
    snap = obs_metrics.snapshot()
    lat = snap["histograms"].get("serving.request_latency_s")
    parts = []
    if lat and lat.get("count"):
        parts.append(f"latency p50={lat['p50']*1e3:.1f}ms "
                     f"p99={lat['p99']*1e3:.1f}ms n={lat['count']}")
    for name, v in snap["counters"].items():
        if v and name.startswith(("distrib.", "serving.")):
            parts.append(f"{name}={v}")
    print(f"[{label}] metrics: " + ("; ".join(parts) or "empty"))


def serve(arch: str = "qwen2-0.5b", *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 16, gen: int = 32, max_seq: int = 128,
          seed: int = 0, temperature: float = 0.0,
          engine: str = "jit", numerics: str = "fast",
          backend: Optional[str] = None) -> Dict[str, Any]:
    """``engine="jit"`` jits one decode step; ``engine="graph"`` drives the
    decode loop through ``Session.run`` with the KV cache as a Variable —
    every token re-runs one cached Executable (DESIGN.md §5).  The graph
    engine defaults to ``numerics="fast"`` (the decode Call + cache Assign
    fuse into one region at full XLA optimization, §9 tolerance contract);
    ``numerics="strict"`` restores bit-parity with unfused execution."""
    cfg = get_config(arch, smoke=smoke)
    model = Model.for_config(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    cache = init_params(
        model.init_cache_desc(batch=batch, max_seq=max_seq),
        jax.random.PRNGKey(1))

    rs = np.random.RandomState(seed)
    prompts = jnp.array(rs.randint(0, cfg.vocab_size, (batch, prompt_len)),
                        jnp.int32)
    frames = None
    if model.is_encdec:
        from ..models import encdec

        frames = jnp.array(
            (rs.randn(batch, cfg.enc_seq, cfg.d_model) * 0.1).astype("f"))
        enc_out = encdec.encode(cfg, model.plan, params, frames)
        ck, cv = encdec.build_cross_cache(cfg, model.plan, params, enc_out)
        cache["cross_k"], cache["cross_v"] = ck, cv

    eb = None
    if engine == "graph":
        eb = build_eager_serve_step(cfg, numerics=numerics,
                                    options=SessionOptions(backend=backend))
        eb.session.set_variable("params", params)
        eb.session.set_variable("cache", cache)

        def step(c, tk, t):
            # the cache lives in the Session's "cache" Variable; the cached
            # Executable's Assign node updates it in place each token
            logits = eb.step({"tokens": tk.astype(jnp.int32), "pos": t})
            return logits, c
    else:
        step = jax.jit(lambda c, tk, t: model.serve_step(params, c, tk, t))

    # --- prefill: feed prompt tokens one step at a time (the cache fills);
    # production prefill lowers the batched forward (launch/steps.py).
    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        logits, cache = step(cache, prompts[:, t:t + 1], jnp.array(t))
    prefill_s = time.time() - t0

    # --- decode: greedy (or temperature) sampling, batched
    out_tokens = []
    key = jax.random.PRNGKey(seed + 1)
    tok = jnp.argmax(logits[:, 0, : cfg.vocab_size], axis=-1)[:, None]
    t0 = time.time()
    for t in range(prompt_len, prompt_len + gen):
        out_tokens.append(np.asarray(tok))
        logits, cache = step(cache, tok.astype(jnp.int32), jnp.array(t))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, 0, : cfg.vocab_size] / temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, 0, : cfg.vocab_size], axis=-1)[:, None]
    decode_s = time.time() - t0

    gen_arr = np.concatenate(out_tokens, axis=1)
    tput = batch * gen / decode_s if decode_s > 0 else float("inf")
    print(f"[serve] arch={cfg.arch_id} engine={engine}"
          f"{'/' + numerics if engine == 'graph' else ''} batch={batch} "
          f"prefill {prefill_s:.2f}s "
          f"decode {decode_s:.2f}s ({tput:.1f} tok/s)")
    res = {"generated": gen_arr, "prefill_s": prefill_s,
           "decode_s": decode_s, "tokens_per_s": tput}
    if eb is not None:
        res["executable_cache"] = eb.session.cache_stats
    return res


def serve_cluster(cluster: str, *, batch: int = 32, requests: int = 100,
                  seed: int = 0, log_every: int = 25,
                  trace_dir: Optional[str] = None,
                  metrics_every: int = 0) -> Dict[str, Any]:
    """DESIGN.md §11 distributed scoring loop over a TCP worker pool.

    Serves the wire-shippable primitive-op MLP's logits: the forward
    graph is partitioned across the ``--cluster`` workers once
    (RegisterGraph), then every request re-runs the cached Executable —
    one RunGraph fan-out with the hidden activations crossing processes
    through the wire rendezvous.  The steady state is the paper's
    serving shape (§3.2 "caches these graphs"), process boundaries
    included.  (The LM decode graph is §15 factory-form and would ship
    too; the MLP keeps this loop fast and dependency-free.)
    """
    from ..core import Session
    from ..distrib.wire import ClusterSpec
    from .steps import build_wire_train_step

    spec = ClusterSpec.parse(cluster)
    tasks = [f"/job:worker/task:{t}" for t in range(len(spec.workers))]
    ws = build_wire_train_step(tasks, seed=seed)
    sess = Session(ws.builder.graph,
                   options=SessionOptions(cluster=spec, trace_dir=trace_dir))
    # fetching only the logits prunes the whole loss/grad/update subgraph
    # (§4.2), so the shipped graph is the pure forward pass
    run = sess.make_callable([ws.logits], [ws.feed_x])
    rs = np.random.RandomState(seed)
    t0 = time.time()
    last = None
    try:
        for r in range(requests):
            x = jnp.asarray(rs.randn(batch, 16).astype("f"))
            t_req = time.time()
            (last,) = run(x)
            obs_metrics.histogram("serving.request_latency_s").observe(
                time.time() - t_req)
            if (r + 1) % log_every == 0:
                rate = (r + 1) / (time.time() - t0)
                print(f"[serve] request {r+1:4d} "
                      f"({rate:.1f} req/s over the wire)")
            if metrics_every and (r + 1) % metrics_every == 0:
                print_metrics()
    finally:
        stats = sess.cache_stats
        sess.close()
    total = time.time() - t0
    rate = requests / total if total > 0 else float("inf")
    print(f"[serve] cluster={','.join(spec.workers)} batch={batch} "
          f"requests={requests} ({rate:.1f} req/s, cache {stats})")
    return {"requests_per_s": rate, "executable_cache": stats,
            "last_logits_shape": tuple(np.asarray(last).shape)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    add_engine_options(ap)
    add_cluster_options(ap)
    add_obs_options(ap)
    ap.add_argument("--requests", type=int, default=100,
                    help="number of scoring requests in --cluster mode")
    args = ap.parse_args(argv)
    if args.cluster:
        serve_cluster(args.cluster, batch=args.batch, requests=args.requests,
                      trace_dir=args.trace_dir,
                      metrics_every=args.metrics_every)
        return 0
    res = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen, engine=args.engine,
                numerics=args.numerics, backend=args.backend)
    print("[serve] sample token ids:", res["generated"][0][:16].tolist())
    if args.metrics_every:
        print_metrics()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
