"""End-to-end training driver (deliverable (b): the e2e example).

Builds the training step AS A repro.core GRAPH (Session + §4.1 gradients
+ optimizer nodes), lowers it (§10), jits it, and drives it from the
§4.5/§4.6 input pipeline with §3.3 periodic checkpointing + restart
recovery.  On CPU use a reduced config; on a pod pass --mesh.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \\
      --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager, FileCheckpointIO
from ..configs import get_config
from ..core.options import SessionOptions
from ..data import SyntheticLMDataset, Prefetcher, batch_iterator
from ..models.api import Shape
from ..models.params import init_params, count_params
from ..obs import metrics as obs_metrics
from ..optim import adamw_init
from .cli import add_cluster_options, add_engine_options, add_obs_options
from .steps import build_train_step, build_eager_train_step


def train(arch: str = "smollm-360m", *, smoke: bool = True, steps: int = 200,
          batch: int = 8, seq: int = 256, lr: float = 1e-3,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
          log_every: int = 10, seed: int = 0,
          resume: bool = True, engine: str = "jit",
          numerics: str = "fast",
          backend: Optional[str] = None,
          summary_dir: Optional[str] = None,
          metrics_every: int = 0) -> Dict[str, Any]:
    """``engine="jit"`` lowers the step graph and jits it (§10);
    ``engine="graph"`` drives the same graph through ``Session.run``, where
    the steady-state loop re-runs one cached Executable per step
    (compile once, run many; DESIGN.md §5).  The graph engine defaults to
    ``numerics="fast"`` — fused regions (incl. matmuls/reductions) compile
    at full XLA optimization under the §9 tolerance contract enforced by
    the CI parity gate; ``numerics="strict"`` restores bit-parity with
    unfused execution."""
    cfg = get_config(arch, smoke=smoke)
    shape = Shape("custom", seq, batch, "train")
    hparam_overrides = {"compute_dtype": jnp.float32,
                        "loss_chunk": 0, "q_chunk": 0}
    eb = None
    if engine == "graph":
        eb = build_eager_train_step(cfg, shape, lr=lr,
                                    hparam_overrides=hparam_overrides,
                                    numerics=numerics,
                                    options=SessionOptions(backend=backend))
        model, graph_nodes = eb.model, eb.graph_nodes
    else:
        sb = build_train_step(cfg, shape, lr=lr,
                              hparam_overrides=hparam_overrides)
        model, graph_nodes = sb.model, sb.graph_nodes
    n_params = count_params(model.describe_params())
    print(f"[train] arch={cfg.arch_id} engine={engine}"
          f"{'/' + numerics if engine == 'graph' else ''} "
          f"params={n_params/1e6:.1f}M "
          f"batch={batch} seq={seq} graph_nodes={graph_nodes}")

    params = init_params(model.describe_params(), jax.random.PRNGKey(seed))
    variables = {"params": params, "opt": adamw_init(params)}
    if engine == "graph":
        def step_fn(feeds, variables):
            # params/opt live in the Session's variable store; the Assign
            # nodes in the cached Executable update them in place.
            return eb.step(feeds), variables
    else:
        step_fn = jax.jit(sb.fn, donate_argnums=(1,))

    mgr = None
    start_step = 0
    if ckpt_dir:
        mgr = CheckpointManager(FileCheckpointIO(ckpt_dir), every_steps=ckpt_every)
        if resume and mgr.latest_step() is not None:
            restored = mgr.restore_latest()
            rv = restored["variables"]
            if not isinstance(rv, dict):
                # cross-process restore: FileCheckpointIO keeps treedefs
                # in-process only and hands back flat leaves — rebuild
                # against the freshly-initialised template structure
                rv = jax.tree.unflatten(jax.tree.structure(variables), rv)
            variables = rv
            start_step = int(mgr.latest_step())
            print(f"[train] resumed from step {start_step} (§3.3 recovery)")
    if engine == "graph":
        for name, value in variables.items():
            eb.session.set_variable(name, value)

    def snapshot_variables() -> Dict[str, Any]:
        return eb.variables() if engine == "graph" else variables

    ds = SyntheticLMDataset(cfg.vocab_size, seq, seed=seed)
    pipe = Prefetcher(batch_iterator(ds, batch, start_step), capacity=4).start()

    writer = None
    if summary_dir or ckpt_dir:  # §9.1: explicit dir, else next to ckpts
        from ..tools import SummaryWriter

        writer = SummaryWriter(summary_dir or os.path.join(ckpt_dir, "events"))

    losses = []
    t0 = time.time()
    for i in range(start_step, steps):
        raw = pipe.get()
        feeds = {"tokens": jnp.asarray(raw["tokens"]),
                 "labels": jnp.asarray(raw["labels"])}
        if model.is_encdec:
            feeds["frames"] = jnp.zeros(
                (batch, cfg.enc_seq, cfg.d_model), jnp.float32)
        t_step = time.time()
        loss, variables = step_fn(feeds, variables)
        losses.append(float(loss))
        if writer:
            writer.add(i + 1, "train/loss", losses[-1])
            writer.add(i + 1, "train/tokens_per_sec",
                       batch * seq / max(time.time() - t_step, 1e-9))
        if mgr and mgr.should_save(i + 1):
            mgr.save(i + 1, {"variables": snapshot_variables()})
        if (i + 1) % log_every == 0:
            rate = (i + 1 - start_step) * batch * seq / (time.time() - t0)
            print(f"[train] step {i+1:5d} loss {float(loss):.4f} "
                  f"({rate:,.0f} tok/s)")
        if metrics_every and (i + 1) % metrics_every == 0:
            snap = obs_metrics.snapshot()
            interesting = {k: v for k, v in snap["counters"].items() if v}
            print(f"[train] metrics step={i+1}: "
                  + (" ".join(f"{k}={v}" for k, v
                              in sorted(interesting.items())) or "empty"))
    pipe.stop()
    if writer:
        writer.close()
    if mgr:
        mgr.save(steps, {"variables": snapshot_variables()})
    out: Dict[str, Any] = {"losses": losses,
                           "final_loss": losses[-1] if losses else None,
                           "params": n_params}
    if engine == "graph":
        out["executable_cache"] = eb.session.cache_stats
    return out


def train_cluster(cluster: str, *, steps: int = 50, batch: int = 64,
                  lr: float = 0.1, ckpt_dir: Optional[str] = None,
                  ckpt_every: int = 10, log_every: int = 10, seed: int = 0,
                  max_recoveries: int = 3, retry_wait: float = 3.0,
                  run_timeout: float = 60.0,
                  standby: Optional[str] = None,
                  trace_dir: Optional[str] = None,
                  metrics_every: int = 0) -> Dict[str, Any]:
    """§3.3/DESIGN.md §11/§13 multi-process training over a TCP pool.

    Drives the wire-shippable primitive-op classifier step
    (``launch/steps.build_wire_train_step``) across ``--cluster
    host:port,...`` workers: place/partition once, RegisterGraph each
    subgraph to its owning process, then one RunGraph fan-out per step
    with Send/Recv riding the wire rendezvous.

    Worker death (heartbeat timeout or transport error) aborts the step.
    Recovery prefers §13 **partial re-placement**: the dead task's
    subgraph is re-placed onto a ``--standby`` worker or a survivor,
    only its Variables restore from the last checkpoint (survivors keep
    live state), and the recovery log says exactly what was kept.  When
    nothing can host the dead task, the whole-pool fallback remains:
    wait for the pool, restore the checkpoint, rebind, resume.

    For data-parallel LM training over the pool (the §15 factory-Call
    step stamped N times), see ``train_replicated`` / ``--replicas N``.
    """
    from ..core import Session
    from ..core.executor import ExecutorError
    from ..distrib.master import RecoveryError
    from ..distrib.wire import ClusterSpec
    from .steps import build_wire_train_step

    spec = ClusterSpec.parse(cluster)
    tasks = [f"/job:worker/task:{t}" for t in range(len(spec.workers))]
    ws = build_wire_train_step(tasks, lr=lr, seed=seed)
    sess = Session(ws.builder.graph,
                   options=SessionOptions(cluster=spec, standby=standby or (),
                                          trace_dir=trace_dir))
    run = sess.make_callable([ws.loss, ws.train_op], [ws.feed_x, ws.feed_y])

    def step_stats_line() -> str:
        """Per-task StepStats from the last run_graph fan-out (§16.4):
        device wall/cpu totals plus wire counters, one clause per task."""
        master = getattr(sess, "_master", None)
        if master is None:
            return ""
        parts = []
        for plan in master.live_plans():
            stats = getattr(plan, "last_run_stats", None) or {}
            for task, st in sorted(stats.items()):
                t = st.get("timings", {})
                wall = sum(d.get("wall_s", 0.0) for d in t.values())
                cpu = sum(d.get("cpu_s", 0.0) for d in t.values())
                parts.append(
                    f"task{task} wall={wall*1e3:.1f}ms cpu={cpu*1e3:.1f}ms "
                    f"sends={st.get('sends', 0)} "
                    f"bytes={st.get('bytes_sent', 0)}")
            if parts:
                break
        return "; ".join(parts)

    def print_cluster_metrics(step: int) -> None:
        """Master-side distrib counters + each live worker's
        ``metrics_snapshot`` digest (§16.4)."""
        snap = obs_metrics.snapshot()
        dist = {k: v for k, v in snap["counters"].items()
                if v and k.startswith("distrib.")}
        print(f"[train] metrics step={step} master: "
              + (" ".join(f"{k}={v}" for k, v in sorted(dist.items()))
                 or "none"))
        master = getattr(sess, "_master", None)
        if master is None:
            return
        for task in range(len(spec.workers)):
            if task in master.dead:
                continue
            try:
                rep = master.channels[task].call("metrics_snapshot",
                                                 _timeout=5.0)
            except Exception:  # noqa: BLE001 — diagnostics stay best-effort
                continue
            h = rep["metrics"]["histograms"].get("worker.device_wall_s") or {}
            if h.get("count"):
                print(f"[train]   task{task}: device wall "
                      f"p50={h['p50']*1e3:.1f}ms p99={h['p99']*1e3:.1f}ms "
                      f"n={h['count']}")
    print(f"[train] cluster={','.join(spec.workers)} tasks={len(tasks)} "
          f"graph_nodes={len(ws.builder.graph.nodes)} (wire step)")

    mgr = None
    start_step = 0
    if ckpt_dir:
        mgr = CheckpointManager(FileCheckpointIO(ckpt_dir), prefix="wire",
                                every_steps=ckpt_every)
        if mgr.latest_step() is not None:
            for name, value in mgr.restore_latest().items():
                sess.set_variable(name, value)
            start_step = int(mgr.latest_step())
            print(f"[train] resumed from step {start_step} (§3.3 recovery)")

    def step_batch(i: int):
        rs = np.random.RandomState(seed * 100003 + i)  # replayable per step
        return (jnp.asarray(rs.randn(batch, 16).astype("f")),
                jnp.asarray(rs.randint(0, 8, (batch,)).astype("i")))

    from ..distrib.protocol import WorkerError

    losses = []
    recoveries = 0
    i = start_step
    t0 = time.time()
    try:
        while i < steps:
            x, y = step_batch(i)
            try:
                loss, _ = run(x, y)
                losses.append(float(loss))
                i += 1
                if mgr and mgr.should_save(i):
                    # the checkpoint pull is inside the recovery scope
                    # too: a worker lost between the step and the save
                    # must trigger recovery, not abort training
                    mgr.save(i, sess.pull_cluster_variables())
                if i % log_every == 0:
                    rate = (i - start_step) / max(time.time() - t0, 1e-9)
                    print(f"[train] step {i:5d} loss {losses[-1]:.4f} "
                          f"({rate:.1f} steps/s over the wire)")
                    stats_line = step_stats_line()
                    if stats_line:
                        print(f"[train] StepStats step={i}: {stats_line}")
                if metrics_every and i % metrics_every == 0:
                    print_cluster_metrics(i)
            except (ExecutorError, WorkerError, OSError) as e:
                if recoveries >= max_recoveries:
                    raise
                recoveries += 1
                print(f"[train] §3.3 worker-pool failure: {e}\n"
                      f"[train] recovery {recoveries}/{max_recoveries}: "
                      f"trying §13 partial re-placement first")
                # --- §13 partial path: re-place only the dead task(s),
                # survivors keep live state; only the dead task's
                # Variables restore from the last checkpoint.
                try:
                    ckpt = (mgr.restore_latest()
                            if mgr and mgr.latest_step() is not None else None)
                    report = sess.recover_dead_tasks(ckpt)
                    if report.mode != "noop":
                        print(report.describe())
                        if ckpt is not None and report.restored:
                            # replacement tasks restart from the checkpoint
                            # step; survivors being ahead is tolerated by
                            # the §4.1 parameter-server async lineage (§13)
                            i = int(mgr.latest_step())
                        continue
                    print("[train] no task marked dead (transient "
                          "transport failure) — whole-pool path")
                except RecoveryError as pe:
                    print(f"[train] partial re-placement unavailable: {pe}\n"
                          f"[train] falling back to whole-pool restart: "
                          f"waiting {retry_wait:.0f}s for the pool, restoring "
                          f"last checkpoint")
                except Exception as pe:  # noqa: BLE001 — replacement died too
                    print(f"[train] partial re-placement failed: {pe}\n"
                          f"[train] falling back to whole-pool restart")
                time.sleep(retry_wait)
                if mgr and mgr.latest_step() is not None:
                    for name, value in mgr.restore_latest().items():
                        sess.set_variable(name, value)
                    i = int(mgr.latest_step())
                else:
                    # no checkpoint yet: try to salvage live state (the
                    # pool may be up with the failure transient);
                    # otherwise the rebind push would overwrite trained
                    # worker weights with the session store's step-0
                    # values, so training must honestly restart at step 0
                    try:
                        salvaged = sess.pull_cluster_variables()
                    except Exception:  # noqa: BLE001 — workers really gone
                        salvaged = {}
                    if not salvaged:
                        print("[train] no checkpoint and worker state "
                              "lost: restarting training from step 0 "
                              "(§3.3 — pass --ckpt-dir to bound the loss)")
                        i = 0
                try:
                    sess.rebind_cluster()  # reconnect + push restored state
                except Exception as re_err:  # noqa: BLE001 — pool still down
                    print(f"[train] pool still unavailable: {re_err}")
                    # a fresh pool re-seeds from the (restored) session
                    # store at registration, so the next attempt is correct
        if mgr:
            mgr.save(steps, sess.pull_cluster_variables())
        if trace_dir:
            path = sess.export_trace()
            if path:
                print(f"[train] wrote merged trace to {path} "
                      f"(load in Perfetto / chrome://tracing)")
    finally:
        sess.close()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "recoveries": recoveries,
            "executable_cache": sess.cache_stats}


def train_replicated(cluster: Optional[str], *, arch: str = "smollm-360m",
                     smoke: bool = True, replicas: int = 4,
                     mode: str = "sync", steps: int = 30, batch: int = 8,
                     seq: int = 64, lr: float = 1e-2, log_every: int = 5,
                     seed: int = 0, numerics: str = "fast",
                     backend: Optional[str] = None) -> Dict[str, Any]:
    """§15 data-parallel LM training: the factory-Call train step stamped
    ``replicas`` times over the ``--cluster`` pool by a ReplicaPlan.

    ``mode="sync"`` runs one barrier step per iteration — every replica's
    gradient flows through the per-Variable reduce tree (Send/Recv over
    the wire) into a single averaged AdamW apply on the parameters' home
    task.  ``mode="async"`` keeps the parameters master-side and drives
    one thread per replica with interleaved applies and no barrier
    (Downpour-style; bounded staleness ~ replicas).  ``cluster=None``
    runs the same plan on in-process devices (testing/benchmarks).
    """
    from ..distrib.replication import ReplicaPlan
    from .steps import build_lm_replica_spec

    cfg = get_config(arch, smoke=smoke)
    shape = Shape("custom", seq, batch, "train")
    spec = build_lm_replica_spec(
        cfg, shape, lr=lr, seed=seed,
        hparam_overrides={"compute_dtype": jnp.float32,
                          "loss_chunk": 0, "q_chunk": 0})
    # parity_guard off: a whole fused train step (loss+grad+adamw+reduce)
    # legitimately drifts past the per-op-class §9 tolerance, and the
    # guard's strict fallback would serialize every step; --numerics
    # strict restores bit-exact execution when that trade is wanted
    plan = ReplicaPlan(spec, replicas, mode=mode, cluster=cluster,
                       options=SessionOptions(numerics=numerics,
                                              backend=backend,
                                              parity_guard=False))
    n_params = sum(np.asarray(x).size
                   for x in jax.tree.leaves(spec.init_values["params"]))
    print(f"[train] replicated arch={cfg.arch_id} replicas={replicas} "
          f"mode={mode} cluster={cluster or 'in-process'} "
          f"params={n_params/1e6:.1f}M batch={batch}x{seq} "
          f"graph_nodes={len(plan.builder.graph.nodes)}")

    def rep_batch(i: int, r: int) -> Dict[str, Any]:
        rs = np.random.RandomState(seed * 1000003 + i * 131 + r)
        return {"tokens": jnp.asarray(
                    rs.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32),
                "labels": jnp.asarray(
                    rs.randint(0, cfg.vocab_size, (batch, seq)), jnp.int32)}

    losses = []
    t0 = time.time()
    try:
        if mode == "sync":
            for i in range(steps):
                shards = [rep_batch(i, r) for r in range(replicas)]
                losses.append(float(plan.step(shards)))
                if (i + 1) % log_every == 0:
                    rate = ((i + 1) * replicas * batch * seq
                            / (time.time() - t0))
                    print(f"[train] step {i+1:5d} loss {losses[-1]:.4f} "
                          f"({rate:,.0f} tok/s across {replicas} replicas)")
        else:
            def on_step(i, r, loss):
                if (len(losses) + 1) % log_every == 0:
                    rate = ((len(losses) + 1) * batch * seq
                            / (time.time() - t0))
                    print(f"[train] apply {len(losses)+1:5d} "
                          f"(replica {r}) loss {loss:.4f} "
                          f"({rate:,.0f} tok/s, interleaved)")
                losses.append(loss)
            plan.run_async(rep_batch, steps, on_step=on_step)
    finally:
        plan.close()
    dt = time.time() - t0
    n_batches = steps * (replicas if mode == "sync" else 1)
    tok_s = n_batches * batch * seq / dt if dt > 0 else float("inf")
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "tok_per_s": tok_s, "mode": mode, "replicas": replicas}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", default=False)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    add_engine_options(ap)
    add_cluster_options(ap, replication=True, standby=True)
    add_obs_options(ap, summary=True)
    ap.set_defaults(smoke=True)
    args = ap.parse_args(argv)
    if args.cluster and args.replicas > 1:
        res = train_replicated(args.cluster, arch=args.arch, smoke=args.smoke,
                               replicas=args.replicas, mode=args.mode,
                               steps=args.steps, batch=args.batch,
                               seq=args.seq, lr=args.lr,
                               numerics=args.numerics, backend=args.backend)
    elif args.cluster:
        res = train_cluster(args.cluster, steps=args.steps, batch=args.batch,
                            lr=args.lr, ckpt_dir=args.ckpt_dir,
                            ckpt_every=args.ckpt_every, standby=args.standby,
                            trace_dir=args.trace_dir,
                            metrics_every=args.metrics_every)
    else:
        res = train(args.arch, smoke=args.smoke, steps=args.steps,
                    batch=args.batch, seq=args.seq, lr=args.lr,
                    ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                    engine=args.engine, numerics=args.numerics,
                    backend=args.backend, summary_dir=args.summary_dir,
                    metrics_every=args.metrics_every)
    print(f"[train] done: final loss {res['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
