"""Trip-count-aware HLO analysis for the roofline (§Roofline).

XLA's ``HloCostAnalysis`` (surfaced via ``compiled.cost_analysis()``)
visits every ``while`` body exactly once, so any model built on
``lax.scan`` over layers under-reports FLOPs/bytes by ~n_layers — useless
for a roofline.  This module re-derives the three terms directly from the
SPMD-partitioned HLO text, multiplying loop bodies by their inferred trip
counts:

  * FLOPs: every ``dot`` (including dots inside fusions), exact from the
    result shape × contracting-dim sizes (symbol table of operand shapes).
  * HBM traffic: fusion boundaries (operands + results of top-level
    instructions) — a *better* proxy for HBM bytes than per-op analysis,
    because XLA fusions keep intermediates in registers/VMEM.
  * Collective wire bytes: ring-model cost per collective kind, group
    size parsed from ``replica_groups``.

Trip counts come from each ``while`` condition's comparison constant.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<rest>.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?(?P<name>[\w\.\-]+)\s*\(.*\{\s*$")
_CALLED_RE = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(dt: str, shape: Tuple[int, ...]) -> int:
    n = _DTYPE_BYTES.get(dt, 0)
    for d in shape:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, List[Tuple[str, Tuple[int, ...]]]]
    instrs: List[Instr]


_OPCODE_RE = re.compile(r"\)\s*(?:\{[^}]*\}\s*)?([a-z][a-z0-9\-]*)\(")


def _split_result_and_op(rest: str) -> Tuple[str, str, List[str]]:
    """rest = '<result-type> <opcode>(<operands>), attrs...'.

    The result type is either ``dtype[dims]{layout}`` or a parenthesised
    tuple of those, so we consume a balanced-paren prefix first.
    """
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        result_part, remainder = rest[: i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return rest, "", []
        result_part, remainder = rest[:sp], rest[sp:]
    m = re.match(r"\s*([a-z][\w\-]*)\(", remainder)
    if not m:
        return result_part, "", []
    opcode = m.group(1)
    paren = remainder.find("(")
    depth = 0
    args = ""
    for ch in remainder[paren:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        args += ch
    operands = re.findall(r"%([\w\.\-]+)", args)
    if not operands:
        operands = [t.strip() for t in args.split(",") if t.strip()]
    return result_part, opcode, operands


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and "->" in line:
                name = m.group("name")
                params: Dict[str, List] = {}
                header = line[line.find("(") + 1: line.rfind("->")]
                for pm in re.finditer(r"%?([\w\.\-]+):\s*([^,]+(?:\([^)]*\))?)",
                                      header):
                    params[pm.group(1)] = _parse_shapes(pm.group(2))
                cur = Computation(name=name, params=params, instrs=[])
            continue
        stripped = line.strip()
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(stripped)
        if not m:
            continue
        rest = m.group("rest")
        result_part, opcode, operands = _split_result_and_op(rest)
        cur.instrs.append(Instr(
            name=m.group("name"), opcode=opcode,
            result_shapes=_parse_shapes(result_part),
            operands=operands, raw=stripped))
    return comps


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: {k: {"count": 0.0, "bytes": 0.0}
                                 for k in _COLLECTIVES})
    loops: List[Tuple[str, int]] = dataclasses.field(default_factory=list)


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = self._find_entry(text)
        self._trip_cache: Dict[str, int] = {}

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(line.strip())
                if m:
                    return m.group("name")
        # fallback: last computation
        return list(self.comps)[-1] if self.comps else ""

    # ------------------------------------------------------------------
    def _symtab(self, comp: Computation) -> Dict[str, List[Tuple[str, Tuple[int, ...]]]]:
        tab = dict(comp.params)
        for ins in comp.instrs:
            tab[ins.name] = ins.result_shapes
        return tab

    def trip_count(self, cond_name: str) -> int:
        if cond_name in self._trip_cache:
            return self._trip_cache[cond_name]
        best = 1
        seen = set()

        def visit(cname):
            nonlocal best
            if cname in seen or cname not in self.comps:
                return
            seen.add(cname)
            for ins in self.comps[cname].instrs:
                for m in re.finditer(r"constant\((\d+)\)", ins.raw):
                    best = max(best, int(m.group(1)))
                for called in _CALLED_RE.findall(ins.raw):
                    visit(called)

        visit(cond_name)
        self._trip_cache[cond_name] = best
        return best

    def _group_size(self, raw: str, default: int) -> int:
        m = _GROUPS_RE.search(raw)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(raw)
        if m:
            return len(m.group(1).split(","))
        return default

    def _dot_flops(self, ins: Instr, symtab) -> float:
        result_elems = 1
        for dt, shape in ins.result_shapes:
            for d in shape:
                result_elems *= d
        mc = _CONTRACT_RE.search(ins.raw)
        lhs_shapes = symtab.get(ins.operands[0]) if ins.operands else None
        if mc is None or not lhs_shapes:
            return 2.0 * result_elems  # fallback: treat as elementwise-ish
        lhs_shape = lhs_shapes[0][1]
        k = 1
        if mc.group(1):
            for dim in mc.group(1).split(","):
                di = int(dim)
                if di < len(lhs_shape):
                    k *= lhs_shape[di]
        return 2.0 * result_elems * k

    def _conv_flops(self, ins: Instr, symtab) -> float:
        # rhs (kernel) elems x result elems x 2 / output-channel size:
        # exact enough for the depthwise convs used here.
        result_elems = 1
        for dt, shape in ins.result_shapes:
            for d in shape:
                result_elems *= d
        rhs = symtab.get(ins.operands[1]) if len(ins.operands) > 1 else None
        if not rhs:
            return 2.0 * result_elems
        rhs_shape = rhs[0][1]
        k = 1
        for d in rhs_shape:
            k *= d
        # depthwise: per output element, kernel_width MACs
        kw = rhs_shape[0] if rhs_shape else 1
        return 2.0 * result_elems * kw

    # ------------------------------------------------------------------
    def analyze(self, n_devices_default: int = 1) -> HloStats:
        stats = HloStats()
        self._walk(self.entry, 1.0, stats, n_devices_default,
                   flops_only=False, depth=0)
        return stats

    def _walk(self, comp_name: str, mult: float, stats: HloStats,
              ndev: int, *, flops_only: bool, depth: int) -> None:
        comp = self.comps.get(comp_name)
        if comp is None or depth > 32:
            return
        symtab = self._symtab(comp)
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                called = dict.fromkeys(_CALLED_RE.findall(ins.raw))
                cond = body = None
                mcond = re.search(r"condition=%?([\w\.\-]+)", ins.raw)
                mbody = re.search(r"body=%?([\w\.\-]+)", ins.raw)
                if mbody:
                    trips = self.trip_count(mcond.group(1)) if mcond else 1
                    stats.loops.append((mbody.group(1), trips))
                    self._walk(mbody.group(1), mult * trips, stats, ndev,
                               flops_only=flops_only, depth=depth + 1)
                continue
            if op == "conditional":
                mb = _BRANCHES_RE.search(ins.raw)
                if mb:
                    for branch in re.findall(r"%?([\w\.\-]+)", mb.group(1)):
                        self._walk(branch, mult, stats, ndev,
                                   flops_only=flops_only, depth=depth + 1)
                continue
            if op in ("call", "async-start"):
                for called in _CALLED_RE.findall(ins.raw):
                    self._walk(called, mult, stats, ndev,
                               flops_only=flops_only, depth=depth + 1)

            # --- collectives (ring model) -------------------------------
            base = op.replace("-start", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                result_bytes = sum(_nbytes(dt, sh) for dt, sh in ins.result_shapes)
                g = self._group_size(ins.raw, ndev)
                if base == "all-reduce":
                    wire = 2.0 * result_bytes * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    wire = float(result_bytes) * (g - 1)
                elif base == "all-gather":
                    wire = result_bytes * (g - 1) / max(g, 1)
                elif base == "all-to-all":
                    wire = result_bytes * (g - 1) / max(g, 1)
                else:  # collective-permute
                    wire = float(result_bytes)
                stats.collectives[base]["count"] += mult
                stats.collectives[base]["bytes"] += mult * wire
                stats.collective_bytes += mult * wire

            # --- flops ---------------------------------------------------
            if op == "dot":
                stats.flops += mult * self._dot_flops(ins, symtab)
            elif op == "convolution":
                stats.flops += mult * self._conv_flops(ins, symtab)
            elif op == "fusion":
                # dots inside fusions still count
                for called in _CALLED_RE.findall(ins.raw):
                    self._walk(called, mult, stats, ndev,
                               flops_only=True, depth=depth + 1)

            # --- HBM traffic at fusion boundaries ------------------------
            if not flops_only and op not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional", "call"):
                rb = sum(_nbytes(dt, sh) for dt, sh in ins.result_shapes)
                ob = 0
                for o in ins.operands:
                    shapes = symtab.get(o)
                    if shapes:
                        ob += sum(_nbytes(dt, sh) for dt, sh in shapes)
                stats.hbm_bytes += mult * (rb + ob)


def analyze_text(text: str, n_devices: int = 1) -> HloStats:
    return HloAnalyzer(text).analyze(n_devices)
