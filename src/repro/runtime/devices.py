"""§3 device naming and registry.

Device names follow the paper's scheme:
``/job:<job>/task:<n>/device:<kind>:<i>`` (or ``/job:localhost`` for the
single-process case).  A :class:`DeviceSet` models the devices visible to
one runtime — for the faithful eager engine these are *virtual* devices
(the paper's heterogeneous CPU/GPU workers); the compiled/pjit path maps
onto real mesh axes instead (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DEV_RE = re.compile(
    r"^/job:(?P<job>[a-z0-9_]+)(/task:(?P<task>\d+))?/device:(?P<kind>[a-z]+):(?P<index>\d+)$"
)


@dataclasses.dataclass(frozen=True)
class DeviceName:
    job: str = "localhost"
    task: int = 0
    kind: str = "cpu"
    index: int = 0

    @staticmethod
    def parse(name: str) -> "DeviceName":
        m = _DEV_RE.match(name)
        if not m:
            raise ValueError(f"bad device name {name!r}")
        return DeviceName(m.group("job"), int(m.group("task") or 0),
                          m.group("kind"), int(m.group("index")))

    def __str__(self) -> str:
        return f"/job:{self.job}/task:{self.task}/device:{self.kind}:{self.index}"


@dataclasses.dataclass
class Device:
    """One computational device: manages kernel execution + a perf model."""

    name: DeviceName
    # cost-model constants used by the §3.2.1 placement simulator
    flops_per_sec: float = 1e11
    bytes_per_sec: float = 5e10  # memory bandwidth
    memory_bytes: int = 16 << 30

    @property
    def kind(self) -> str:
        return self.name.kind


class DeviceSet:
    def __init__(self, devices: Optional[List[Device]] = None) -> None:
        self.devices: Dict[str, Device] = {}
        for d in devices or [Device(DeviceName())]:
            self.devices[str(d.name)] = d

    @staticmethod
    def make_local(n_cpu: int = 1, n_accel: int = 0, accel_kind: str = "tpu",
                   accel_flops: float = 2e14, accel_bw: float = 8e11) -> "DeviceSet":
        devs = [Device(DeviceName(kind="cpu", index=i)) for i in range(n_cpu)]
        devs += [
            Device(DeviceName(kind=accel_kind, index=i),
                   flops_per_sec=accel_flops, bytes_per_sec=accel_bw)
            for i in range(n_accel)
        ]
        return DeviceSet(devs)

    @staticmethod
    def make_cluster(n_workers: int, devices_per_worker: int = 1,
                     kind: str = "tpu") -> "DeviceSet":
        devs = []
        for t in range(n_workers):
            for i in range(devices_per_worker):
                devs.append(Device(DeviceName(job="worker", task=t, kind=kind, index=i)))
        return DeviceSet(devs)

    def names(self) -> List[str]:
        return list(self.devices)

    def fingerprint(self) -> tuple:
        """Hashable identity of this device set, used in RunSignatures so
        swapping the Session's devices invalidates cached Executables."""
        return tuple(sorted(self.devices))

    def __getitem__(self, name: str) -> Device:
        return self.devices[name]

    def __len__(self) -> int:
        return len(self.devices)

    def feasible(self, kinds) -> List[str]:
        return [n for n, d in self.devices.items() if d.kind in kinds]

    def matches(self, constraint: Optional[str]) -> List[str]:
        """§4.3 partial constraints: a constraint is a device-name *prefix*
        (e.g. "/job:worker/task:17") or a kind pattern "device:gpu"."""
        if not constraint:
            return self.names()
        out = []
        for n in self.devices:
            if n.startswith(constraint) or constraint in n:
                out.append(n)
        return out
