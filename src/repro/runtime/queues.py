"""§4.6 Queues: FIFO + shuffling, with blocking Enqueue/Dequeue.

Enqueue blocks until space is available; Dequeue blocks until the
requested minimum number of elements is present.  The shuffling queue
randomizes within a large in-memory buffer (used for example shuffling).
These also implement the §5.3 asynchronous-kernel story in the eager
runtime: the blocking happens inside the kernel without burning the
scheduler.
"""
from __future__ import annotations

import random
import threading
from typing import Any, List, Optional, Tuple


class QueueClosed(Exception):
    pass


class FIFOQueue:
    def __init__(self, capacity: int = 1024, timeout: float = 30.0, name: str = "fifo") -> None:
        self.capacity = capacity
        self.timeout = timeout
        self.name = name
        self._items: List[Any] = []
        self._cv = threading.Condition()
        self._closed = False

    def enqueue(self, item: Any) -> None:
        with self._cv:
            ok = self._cv.wait_for(
                lambda: len(self._items) < self.capacity or self._closed, timeout=self.timeout)
            if self._closed:
                raise QueueClosed(self.name)
            if not ok:
                raise TimeoutError(f"enqueue timed out on {self.name!r}")
            self._items.append(item)
            self._cv.notify_all()

    def enqueue_many(self, items) -> None:
        for it in items:
            self.enqueue(it)

    def _pick(self) -> Any:
        return self._items.pop(0)

    def dequeue(self) -> Any:
        with self._cv:
            ok = self._cv.wait_for(lambda: self._items or self._closed, timeout=self.timeout)
            if self._items:
                it = self._pick()
                self._cv.notify_all()
                return it
            if self._closed:
                raise QueueClosed(self.name)
            raise TimeoutError(f"dequeue timed out on {self.name!r}")

    def dequeue_many(self, n: int) -> List[Any]:
        """Blocks until ``n`` elements are available (the paper's minimum)."""
        out = []
        with self._cv:
            ok = self._cv.wait_for(lambda: len(self._items) >= n or self._closed,
                                   timeout=self.timeout)
            if len(self._items) >= n:
                for _ in range(n):
                    out.append(self._pick())
                self._cv.notify_all()
                return out
            if self._closed:
                raise QueueClosed(self.name)
            raise TimeoutError(f"dequeue_many timed out on {self.name!r}")

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def size(self) -> int:
        with self._cv:
            return len(self._items)


class ShufflingQueue(FIFOQueue):
    """Randomly shuffles elements within its in-memory buffer (§4.6).

    ``min_after_dequeue`` is a *pre-fill target*, not a hard gate: each
    dequeue first gives the producer a bounded grace period
    (``prefill_grace``) to build the window up to the target, then
    serves whatever is buffered.  A fast producer therefore yields a
    real shuffle window (the deflake contract for
    ``Prefetcher(shuffle=True)``); a slow producer degrades the window
    instead of stalling the stream into a TimeoutError.
    """

    def __init__(self, capacity: int = 1024, min_after_dequeue: int = 0,
                 seed: Optional[int] = None, timeout: float = 30.0,
                 name: str = "shuffle", prefill_grace: float = 1.0) -> None:
        super().__init__(capacity=capacity, timeout=timeout, name=name)
        self.min_after_dequeue = min_after_dequeue
        self.prefill_grace = prefill_grace
        self._rng = random.Random(seed)

    def _pick(self) -> Any:
        idx = self._rng.randrange(len(self._items))
        return self._items.pop(idx)

    def dequeue(self) -> Any:
        with self._cv:
            need = self.min_after_dequeue + 1
            if len(self._items) < need and not self._closed:
                self._cv.wait_for(
                    lambda: len(self._items) >= need or self._closed,
                    timeout=min(self.timeout, self.prefill_grace))
            self._cv.wait_for(lambda: bool(self._items) or self._closed,
                              timeout=self.timeout)
            if self._items:
                it = self._pick()
                self._cv.notify_all()
                return it
            if self._closed:
                raise QueueClosed(self.name)
            raise TimeoutError(f"dequeue timed out on {self.name!r}")
