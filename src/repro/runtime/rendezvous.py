"""§3.2.2 Send/Recv rendezvous.

Send and Receive coordinate through a keyed rendezvous so that all
communication is isolated inside the Send/Recv implementations.  Keys are
``(tensor_ref, src_device, dst_device, execution_id)`` strings; the
canonicalisation pass guarantees one transfer per (tensor, device-pair).
The local implementation hands arrays across a thread-safe table; the
distributed implementation that swaps TCP underneath the same interface
is :class:`repro.distrib.wire.WireRendezvous` (DESIGN.md §11), which
wraps one of these tables as the worker's process-wide mailbox — on TPU
pods this role is played by XLA collectives instead (DESIGN.md §2).
"""
from __future__ import annotations

import threading
from typing import Any, Dict


def make_key(tensor: str, src: str, dst: str, execution_id: int = 0) -> str:
    return f"{src};{dst};{tensor};{execution_id}"


class _DeadTensor:
    """Wire marker for a §4.4 dead tensor.

    When control flow spans devices, deadness must cross the wire: a Send
    whose input is dead (untaken cond branch, or the loop's terminating
    iteration) transmits this marker so the receiving device's consumers
    learn the value is dead and propagate it, instead of blocking forever
    on a tensor that will never be produced.
    """

    __slots__ = ()

    def __repr__(self) -> str:
        return "<dead tensor>"


DEAD_TENSOR = _DeadTensor()


class Rendezvous:
    def __init__(self, timeout: float = 30.0) -> None:
        self._table: Dict[str, Any] = {}
        self._cv = threading.Condition()
        self.timeout = timeout
        self.sends = 0  # instrumentation for tests/benchmarks
        self.bytes_sent = 0
        self._dead: Any = None  # §3.3: exception poisoning all waiters

    def send(self, key: str, value: Any) -> None:
        with self._cv:
            if self._dead is not None:
                raise self._dead
            if key in self._table:
                raise RuntimeError(f"duplicate send for rendezvous key {key!r}")
            self._table[key] = value
            self.sends += 1
            try:
                self.bytes_sent += value.nbytes
            except AttributeError:
                pass
            self._cv.notify_all()

    def ready(self, key: str) -> bool:
        """Non-blocking probe: has ``key`` been sent (and not yet consumed)?
        Used by the executor to defer Recv nodes while other local work is
        runnable instead of blocking its single dispatch thread."""
        with self._cv:
            return key in self._table

    def wait_any(self, keys, timeout: float = None) -> str:
        """Block until ANY of ``keys`` has been sent; returns that key
        without consuming it.  The executor uses this when every runnable
        node on a device is a not-yet-ready Recv — blocking on one
        arbitrary key could pick a tensor the peer produces *last* and
        deadlock the pair."""
        keys = list(keys)
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self._dead is not None
                or any(k in self._table for k in keys),
                timeout=self.timeout if timeout is None else timeout)
            if not ok:
                raise TimeoutError(f"recv timed out waiting for any of {keys!r}")
            for k in keys:
                if k in self._table:
                    return k
            if self._dead is not None:
                raise self._dead
            raise RuntimeError("unreachable: wait_any predicate satisfied")

    def recv(self, key: str, timeout: float = None) -> Any:
        with self._cv:
            ok = self._cv.wait_for(
                lambda: self._dead is not None or key in self._table,
                timeout=self.timeout if timeout is None else timeout)
            if not ok:
                raise TimeoutError(f"recv timed out waiting for {key!r}")
            if key in self._table:
                return self._table.pop(key)
            raise self._dead

    def abort(self, exc: BaseException) -> None:
        """§3.3: poison the table — every blocked or future send/recv
        raises ``exc``.  Used on worker shutdown so RPC handler threads
        blocked in ``recv`` unwind instead of holding their sockets."""
        with self._cv:
            self._dead = exc
            self._cv.notify_all()

    def pending_keys(self) -> list:
        """Keys currently deposited and unconsumed — the §13 hygiene
        probe (``debug_state`` RPC): after an aborted execution is purged
        the mailbox must hold nothing under that execution's prefix."""
        with self._cv:
            return sorted(self._table)

    def purge_prefix(self, prefix: str) -> int:
        """Drop every key starting with ``prefix`` (per-execution cleanup
        of the distributed mailbox; DESIGN.md §11)."""
        with self._cv:
            stale = [k for k in self._table if k.startswith(prefix)]
            for k in stale:
                del self._table[k]
            return len(stale)

    def reset(self) -> None:
        with self._cv:
            self._table.clear()
            self.sends = 0
            self.bytes_sent = 0
            self._dead = None
