"""§3.2.2 Send/Recv rendezvous.

Send and Receive coordinate through a keyed rendezvous so that all
communication is isolated inside the Send/Recv implementations.  Keys are
``(tensor_ref, src_device, dst_device, execution_id)`` strings; the
canonicalisation pass guarantees one transfer per (tensor, device-pair).
The local implementation hands arrays across a thread-safe table; a
distributed implementation would swap TCP/RDMA underneath the same
interface — on TPU pods this role is played by XLA collectives instead
(DESIGN.md §2).
"""
from __future__ import annotations

import threading
from typing import Any, Dict


def make_key(tensor: str, src: str, dst: str, execution_id: int = 0) -> str:
    return f"{src};{dst};{tensor};{execution_id}"


class Rendezvous:
    def __init__(self, timeout: float = 30.0) -> None:
        self._table: Dict[str, Any] = {}
        self._cv = threading.Condition()
        self.timeout = timeout
        self.sends = 0  # instrumentation for tests/benchmarks
        self.bytes_sent = 0

    def send(self, key: str, value: Any) -> None:
        with self._cv:
            if key in self._table:
                raise RuntimeError(f"duplicate send for rendezvous key {key!r}")
            self._table[key] = value
            self.sends += 1
            try:
                self.bytes_sent += value.nbytes
            except AttributeError:
                pass
            self._cv.notify_all()

    def recv(self, key: str) -> Any:
        with self._cv:
            ok = self._cv.wait_for(lambda: key in self._table, timeout=self.timeout)
            if not ok:
                raise TimeoutError(f"recv timed out waiting for {key!r}")
            return self._table.pop(key)

    def reset(self) -> None:
        with self._cv:
            self._table.clear()
            self.sends = 0
            self.bytes_sent = 0
