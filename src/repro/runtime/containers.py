"""§4.7 Containers — longer-lived mutable state backing Variables.

The default container persists until the process terminates; named
containers can be reset independently.  Containers are shared across
Sessions, which is exactly how the paper lets disjoint graphs share state.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional


class Container:
    def __init__(self, name: str = "") -> None:
        self.name = name
        self._values: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def read(self, var_name: str, init: Optional[Callable[[], Any]] = None) -> Any:
        with self._lock:
            if var_name not in self._values:
                if init is None:
                    raise KeyError(f"uninitialized variable {var_name!r} in container {self.name!r}")
                self._values[var_name] = init()
            return self._values[var_name]

    def write(self, var_name: str, value: Any) -> None:
        with self._lock:
            self._values[var_name] = value

    def has(self, var_name: str) -> bool:
        with self._lock:
            return var_name in self._values

    def reset(self) -> None:
        with self._lock:
            self._values.clear()

    def keys(self):
        with self._lock:
            return list(self._values)


class ContainerManager:
    """Process-wide named containers (the §4.7 resource manager)."""

    def __init__(self) -> None:
        self._containers: Dict[str, Container] = {"": Container("")}
        self._lock = threading.Lock()

    def get(self, name: str = "") -> Container:
        with self._lock:
            if name not in self._containers:
                self._containers[name] = Container(name)
            return self._containers[name]

    def reset(self, name: str = "") -> None:
        self.get(name).reset()


DEFAULT_MANAGER = ContainerManager()


class VariableStore:
    """Adapter the executor uses: resolves each Variable node's container."""

    def __init__(self, manager: Optional[ContainerManager] = None) -> None:
        self.manager = manager or ContainerManager()

    def read(self, var_name: str, attrs: Dict[str, Any]) -> Any:
        cont = self.manager.get(attrs.get("container", ""))
        init = attrs.get("init")
        init_fn = (init if callable(init) else (lambda: init)) if init is not None else None
        return cont.read(var_name, init_fn)

    def write(self, var_name: str, value: Any, container: str = "") -> None:
        # Variables live where first initialized; search known containers.
        for cname in list(self.manager._containers):
            c = self.manager.get(cname)
            if c.has(var_name):
                c.write(var_name, value)
                return
        self.manager.get(container).write(var_name, value)

    def has(self, var_name: str) -> bool:
        return any(self.manager.get(c).has(var_name) for c in list(self.manager._containers))
