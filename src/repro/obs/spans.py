"""Span recording for the distributed EEG (DESIGN.md §16).

A :class:`SpanRecorder` is a thread-safe append-only buffer of start/end
events.  Executors, the wire layer and the RPC client each record into
one when tracing is enabled; when it is not, every instrumentation site
reduces to a single ``is None`` check — the off path allocates nothing
and takes no locks (asserted by benchmark b15).

Timestamps are ``time.time()`` (epoch seconds) rather than a process
monotonic clock: merging streams from several processes then reduces to
subtracting one estimated clock offset per stream (§16.3), instead of
reconstructing per-process epochs.  Durations stay meaningful because a
span's start and end are read in the same process.

Span categories (the ``cat`` field):

========== ==============================================================
``op``         one runtime op executed by an executor
``region``     one FusedRegion dispatch — a single span for the whole
               jitted super-node (never demoted to per-member events)
``wait``       time blocked on the rendezvous (Recv not ready, or a
               deferral ``wait_any``) — rendered on its own lane
``rpc``        client side of a wire RPC (``Channel._call_once``)
``rpc-server`` server side of a wire RPC (worker serve loop)
``step``       one whole training step (launch layer)
========== ==============================================================
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

CAT_OP = "op"
CAT_REGION = "region"
CAT_WAIT = "wait"
CAT_RPC = "rpc"
CAT_RPC_SERVER = "rpc-server"
CAT_STEP = "step"


class SpanRecorder:
    """Thread-safe buffer of span events for one process (or one run).

    An event is a plain dict — ``{"name", "cat", "device", "ts", "dur"}``
    plus an optional ``"args"`` — with ``ts``/``dur`` in epoch seconds
    (converted to microseconds only at export time).  Events are picklable
    as-is so worker buffers ship over the wire unchanged.
    """

    def __init__(self, process: str = "local") -> None:
        self.process = process
        self._events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()

    @staticmethod
    def now() -> float:
        return time.time()

    def record(self, name: str, cat: str, device: str,
               t_start: float, t_end: float,
               args: Optional[Dict[str, Any]] = None) -> None:
        e: Dict[str, Any] = {
            "name": name,
            "cat": cat,
            "device": device,
            "ts": t_start,
            "dur": max(t_end - t_start, 1e-8),
        }
        if args:
            e["args"] = args
        with self._lock:
            self._events.append(e)

    def drain(self) -> List[Dict[str, Any]]:
        """Return all buffered events and clear the buffer."""
        with self._lock:
            out, self._events = self._events, []
        return out

    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def extend(self, events: List[Dict[str, Any]]) -> None:
        with self._lock:
            self._events.extend(events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


# ---------------------------------------------------------------------------
# Process-global recorder.  The RPC client (distrib/protocol.py) cannot be
# handed a recorder per call, so it consults this slot; ``get()`` is the
# whole cost of the disabled path.

_GLOBAL: Optional[SpanRecorder] = None


def get() -> Optional[SpanRecorder]:
    return _GLOBAL


def enabled() -> bool:
    return _GLOBAL is not None


def install(recorder: Optional[SpanRecorder]) -> Optional[SpanRecorder]:
    """Install (or clear, with None) the process-global recorder."""
    global _GLOBAL
    _GLOBAL = recorder
    return recorder
