"""Observability: distributed EEG spans + unified metrics (DESIGN.md §16).

- :mod:`repro.obs.spans` — cheap start/end span events from the real
  execution paths (fused executors, wire RPCs, rendezvous waits).
- :mod:`repro.obs.metrics` — the process-global registry of named
  counters/gauges/histograms (absorbs the legacy ``STATS`` dicts).
- :mod:`repro.obs.export` — merges per-process streams into one
  Chrome-trace/Perfetto JSON with clock-offset alignment.
- :mod:`repro.obs.profile` — ``python -m repro.obs.profile`` summary CLI.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, REGISTRY,
                      StatsDict)
from .spans import SpanRecorder
from .export import merge_streams, validate_trace, write_trace

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "StatsDict", "SpanRecorder", "merge_streams", "validate_trace",
    "write_trace",
]
