"""Merge span streams into one Chrome-trace/Perfetto JSON (DESIGN.md §16.3).

A *stream* is one process's events plus the clock-offset estimate that
aligns it with the master's clock::

    {"process": "worker-task1", "offset_s": 0.0031, "events": [...]}

``merge_streams`` lays the result out as the EEG does: one pid per
process (named via ``process_name`` metadata), one tid per device inside
it, plus a dedicated ``rendezvous`` lane per process that collects the
``wait`` spans — stall time is visible as its own track instead of being
buried inside Recv compute.  Timestamps are normalised so the earliest
event across all streams lands at t=0.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List

from . import spans as _spans

RENDEZVOUS_LANE = "rendezvous"


def merge_streams(streams: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Merge per-process span streams into one Chrome-trace object."""
    streams = [s for s in streams if s.get("events")]
    if not streams:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    t0 = min(e["ts"] - s.get("offset_s", 0.0)
             for s in streams for e in s["events"])

    events: List[Dict[str, Any]] = []
    for pid, stream in enumerate(streams, start=1):
        process = str(stream.get("process", f"process{pid}"))
        offset = float(stream.get("offset_s", 0.0))
        tid_of: Dict[str, int] = {}

        def tid(lane: str) -> int:
            if lane not in tid_of:
                tid_of[lane] = len(tid_of) + 1
                events.append({"name": "thread_name", "ph": "M", "pid": pid,
                               "tid": tid_of[lane], "cat": "__metadata",
                               "args": {"name": f"{process}/{lane}"}})
            return tid_of[lane]

        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "cat": "__metadata",
                       "args": {"name": process}})

        for e in stream["events"]:
            cat = e.get("cat", _spans.CAT_OP)
            lane = RENDEZVOUS_LANE if cat == _spans.CAT_WAIT \
                else str(e.get("device", "?"))
            args = dict(e.get("args", ()))
            op = args.get("op")
            if cat == _spans.CAT_REGION:
                name = f"FusedRegion:{e['name']}"
            elif op:
                name = f"{op}:{e['name']}"
            else:
                name = str(e["name"])
            events.append({
                "name": name,
                "cat": cat,
                "ph": "X",
                "pid": pid,
                "tid": tid(lane),
                "ts": (e["ts"] - offset - t0) * 1e6,
                "dur": max(e["dur"] * 1e6, 0.01),
                "args": args,
            })

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_trace(path: str, streams: Iterable[Dict[str, Any]]) -> str:
    """Write the merged trace JSON; returns the path written."""
    obj = merge_streams(streams)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f)
    return path


def validate_trace(obj: Dict[str, Any]) -> Dict[str, Any]:
    """Schema-check a merged trace; raises ``ValueError`` on violation.

    Returns ``{"events": N, "processes": [...], "lanes": [...]}`` so
    callers (the CI smoke job) can additionally assert lane coverage.
    """
    evs = obj.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        raise ValueError("trace has no traceEvents")
    processes, lanes = [], []
    for e in evs:
        if not isinstance(e, dict):
            raise ValueError(f"non-dict event: {e!r}")
        missing = {"name", "ph", "pid", "tid"} - set(e)
        if missing:
            raise ValueError(f"event missing {sorted(missing)}: {e!r}")
        if e["ph"] == "M":
            if e["name"] == "process_name":
                processes.append(e["args"]["name"])
            elif e["name"] == "thread_name":
                lanes.append(e["args"]["name"])
        elif e["ph"] == "X":
            if "ts" not in e or "dur" not in e:
                raise ValueError(f"X event missing ts/dur: {e!r}")
            if e["ts"] < 0 or e["dur"] <= 0:
                raise ValueError(f"non-causal event: {e!r}")
        else:
            raise ValueError(f"unexpected phase {e['ph']!r}")
    return {"events": sum(1 for e in evs if e["ph"] == "X"),
            "processes": processes, "lanes": lanes}
