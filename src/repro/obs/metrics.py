"""Process-global metrics registry (DESIGN.md §16.4).

One named home for the counters that used to live in scattered
module-level ``STATS`` dicts, plus gauges and latency histograms for the
serving tier.  Names follow ``<subsystem>.<metric>[.<detail>]``
(``fusion.regions_built``, ``distrib.rpc_retries``,
``serving.request_latency_s``); the full scheme is documented in
DESIGN.md §16.4.

The legacy dicts keep working through :class:`StatsDict`, a
``MutableMapping`` whose items are registry counters — ``STATS["x"] += 1``
still reads naturally at the call site but the value is now visible in
``snapshot()`` and over the ``metrics_snapshot`` RPC.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Dict, Iterator, List, MutableMapping, Optional, Tuple


class Counter:
    """A monotonic-by-convention integer counter (``set`` exists so the
    legacy ``for k in STATS: STATS[k] = 0`` reset idiom keeps working)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    def set(self, v: int) -> None:
        with self._lock:
            self._value = int(v)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """A last-write-wins float sample (e.g. a last-progress timestamp)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value: Optional[float] = None

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> Optional[float]:
        return self._value


class Histogram:
    """Latency histogram with exact count/sum and a bounded reservoir of
    the most recent observations for quantiles.  2048 samples bound both
    memory and the sort cost of a ``percentile`` call while keeping
    p50/p99 of the recent window accurate — the serving numbers ROADMAP
    item 1 asks for are windowed anyway."""

    RESERVOIR = 2048

    __slots__ = ("name", "count", "sum", "_recent", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self.count = 0
        self.sum = 0.0
        self._recent: deque = deque(maxlen=self.RESERVOIR)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self._recent.append(v)

    def percentile(self, p: float) -> Optional[float]:
        with self._lock:
            if not self._recent:
                return None
            xs = sorted(self._recent)
        idx = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
        return xs[idx]

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            xs = sorted(self._recent)
            count, total = self.count, self.sum
        if not xs:
            return {"count": count, "sum": total}
        pick = lambda p: xs[min(len(xs) - 1, int(round(p * (len(xs) - 1))))]
        return {"count": count, "sum": total, "min": xs[0], "max": xs[-1],
                "p50": pick(0.50), "p90": pick(0.90), "p99": pick(0.99)}


class MetricsRegistry:
    """Get-or-create registry of named counters/gauges/histograms."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name)
            return h

    def snapshot(self) -> Dict[str, Any]:
        """A plain-dict, picklable view of everything registered — the
        payload of the ``metrics_snapshot`` RPC."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {n: c.value for n, c in sorted(counters.items())},
            "gauges": {n: g.value for n, g in sorted(gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(histograms.items())},
        }


REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def snapshot() -> Dict[str, Any]:
    return REGISTRY.snapshot()


class StatsDict(MutableMapping):
    """Module-level ``STATS`` dict, registry-backed.

    Drop-in for the old ad-hoc dicts: iteration order is insertion
    order, missing keys raise ``KeyError``, and ``STATS[k] = v`` both
    declares the key and sets the counter.  Every key ``k`` is the
    registry counter ``<prefix>.<k>``, so existing call sites keep their
    shape while the values surface in :func:`snapshot`.
    """

    def __init__(self, prefix: str, keys: Tuple[str, ...] = (),
                 registry: Optional[MetricsRegistry] = None) -> None:
        self._prefix = prefix
        self._registry = registry if registry is not None else REGISTRY
        self._keys: List[str] = []
        for k in keys:
            self[k] = 0

    def _counter(self, key: str) -> Counter:
        return self._registry.counter(f"{self._prefix}.{key}")

    def __getitem__(self, key: str) -> int:
        if key not in self._keys:
            raise KeyError(key)
        return self._counter(key).value

    def __setitem__(self, key: str, value: int) -> None:
        if key not in self._keys:
            self._keys.append(key)
        self._counter(key).set(value)

    def __delitem__(self, key: str) -> None:
        self._keys.remove(key)

    def __iter__(self) -> Iterator[str]:
        return iter(list(self._keys))

    def __len__(self) -> int:
        return len(self._keys)

    def __repr__(self) -> str:
        return repr({k: self[k] for k in self._keys})
