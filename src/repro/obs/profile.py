"""Offline profile reader for merged EEG traces (DESIGN.md §16.5).

::

    python -m repro.obs.profile /tmp/trace/trace.json [--top 10]
                                [--stalls-over-us 100] [--validate]

Renders the per-op / per-region / per-RPC time summary from a merged
Chrome-trace JSON produced by ``Session(trace_dir=)``, plus the top
rendezvous stalls — the textual equivalent of eyeballing the EEG lanes.
``--validate`` additionally schema-checks the file and exits non-zero on
violation (used by the CI smoke job).
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List

from . import spans as _spans
from .export import validate_trace


def _rows(events: List[Dict[str, Any]], cat: str) -> List[Dict[str, Any]]:
    acc: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "total_us": 0.0, "max_us": 0.0})
    for e in events:
        if e.get("ph") != "X" or e.get("cat") != cat:
            continue
        key = e["name"].split(":", 1)[0] if cat == _spans.CAT_OP else e["name"]
        r = acc[key]
        r["count"] += 1
        r["total_us"] += e["dur"]
        r["max_us"] = max(r["max_us"], e["dur"])
    return sorted(({"name": k, **v} for k, v in acc.items()),
                  key=lambda r: -r["total_us"])


def _table(title: str, rows: List[Dict[str, Any]], top: int) -> List[str]:
    out = [f"## {title}"]
    if not rows:
        out.append("  (none)")
        return out
    out.append(f"  {'name':<40} {'count':>7} {'total_us':>12} {'max_us':>10}")
    for r in rows[:top]:
        out.append(f"  {r['name']:<40} {r['count']:>7} "
                   f"{r['total_us']:>12.1f} {r['max_us']:>10.1f}")
    if len(rows) > top:
        out.append(f"  ... {len(rows) - top} more")
    return out


def render(obj: Dict[str, Any], *, top: int = 10,
           stalls_over_us: float = 100.0) -> str:
    events = [e for e in obj.get("traceEvents", []) if isinstance(e, dict)]
    lines: List[str] = []
    lines += _table("ops", _rows(events, _spans.CAT_OP), top)
    lines += _table("fused regions", _rows(events, _spans.CAT_REGION), top)
    lines += _table("rpcs (client)", _rows(events, _spans.CAT_RPC), top)
    lines += _table("rpcs (server)", _rows(events, _spans.CAT_RPC_SERVER), top)

    stalls = sorted((e for e in events
                     if e.get("ph") == "X" and e.get("cat") == _spans.CAT_WAIT
                     and e.get("dur", 0.0) >= stalls_over_us),
                    key=lambda e: -e["dur"])
    lines.append(f"## top rendezvous stalls (>= {stalls_over_us:.0f}us)")
    if not stalls:
        lines.append("  (none)")
    for e in stalls[:top]:
        lines.append(f"  {e['dur']:>10.1f}us  pid={e['pid']} {e['name']}")
    return "\n".join(lines)


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.profile",
        description="per-op/per-region/per-RPC summary of a merged EEG trace")
    ap.add_argument("trace", help="path to a merged Chrome-trace JSON")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--stalls-over-us", type=float, default=100.0)
    ap.add_argument("--validate", action="store_true",
                    help="schema-check the trace; exit 1 on violation")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        obj = json.load(f)

    if args.validate:
        try:
            info = validate_trace(obj)
        except ValueError as e:
            print(f"INVALID: {e}", file=sys.stderr)
            return 1
        print(f"valid: {info['events']} events, "
              f"processes={info['processes']}, lanes={info['lanes']}")

    print(render(obj, top=args.top, stalls_over_us=args.stalls_over_us))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
